"""Parity harness: the bit-packed storage path must reproduce the
unpacked path bit-for-bit (integer-domain truncation and unpack are
exact) across the whole encode -> store -> scan surface, for budgets
B in {0.5, 1, 2, 4, 8} and prefix-bits settings:

* SAQ.estimate_dist_sq / segment_ip on the flat container
* the fused Pallas scan (saq_scan_pallas, interpret mode)
* IVFIndex.search_batch / search_multistage over the word buffer
* recall@10 of the packed vs unpacked index (the acceptance criterion)
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.saq import SAQConfig, fit_saq
from repro.ivf import IVFIndex
from repro.ivf.index import brute_force_topk
from repro.kernels import ops
from conftest import decaying_data

BUDGETS = (0.5, 1, 2, 4, 8)
N, D = 700, 48


@pytest.fixture(scope="module", params=BUDGETS, ids=lambda b: f"B{b}")
def fitted(request):
    b = request.param
    x = decaying_data(N, D, alpha=0.8, seed=17)
    saq = fit_saq(x, avg_bits=float(b), rounds=2, align=8, max_bits=8,
                  seed=1)
    qds_packed = saq.encode(x)                       # bitpacked default
    qds_cols = saq.encode(x, bitpacked=False)
    qs = decaying_data(6, D, alpha=0.8, seed=170)
    return b, x, saq, qds_packed, qds_cols, qs


def prefix_settings(layout):
    """None (native) plus an aggressive per-segment truncation."""
    if layout.n_segments == 0:
        return [None]
    return [None, [max(1, b // 2) for b in layout.seg_bits]]


def test_storage_modes_differ_but_decode_same(fitted):
    _, _, saq, qp, qc, _ = fitted
    assert qp.bitpacked and not qc.bitpacked
    if qp.layout.n_segments:
        assert qp.codes.dtype == jnp.uint32
        assert qp.codes.shape[-1] == qp.layout.n_words
    np.testing.assert_array_equal(np.asarray(qp.code_matrix()),
                                  np.asarray(qc.codes))
    np.testing.assert_array_equal(np.asarray(saq.decode(qp)),
                                  np.asarray(saq.decode(qc)))


def test_estimators_bit_identical(fitted):
    _, _, saq, qp, qc, qs = fitted
    qcs = saq.preprocess_queries(jnp.asarray(qs))
    for pb in prefix_settings(qp.layout):
        ip_p = np.asarray(saq.segment_ip(qp, qcs, prefix_bits=pb))
        ip_c = np.asarray(saq.segment_ip(qc, qcs, prefix_bits=pb))
        np.testing.assert_array_equal(ip_p, ip_c)
        d_p = np.asarray(saq.estimate_dist_sq(qp, qcs, prefix_bits=pb))
        d_c = np.asarray(saq.estimate_dist_sq(qc, qcs, prefix_bits=pb))
        np.testing.assert_array_equal(d_p, d_c)


def test_fused_kernel_bit_identical(fitted):
    """saq_scan_pallas reading VMEM-resident words == reading columns."""
    _, _, saq, qp, qc, qs = fitted
    if qp.layout.n_segments == 0:
        pytest.skip("plan stores no segments")
    qcs = saq.preprocess_queries(jnp.asarray(qs))
    for pb in prefix_settings(qp.layout):
        k_p = np.asarray(ops.saq_scan(qp, qcs.q_rot,
                                      q_norm_sq=qcs.q_norm_sq,
                                      prefix_bits=pb))
        k_c = np.asarray(ops.saq_scan(qc, qcs.q_rot,
                                      q_norm_sq=qcs.q_norm_sq,
                                      prefix_bits=pb))
        np.testing.assert_array_equal(k_p, k_c)


@pytest.fixture(scope="module")
def indexes(fitted):
    b, x, _, _, _, _ = fitted
    idx = IVFIndex.build(
        x, SAQConfig(avg_bits=float(b), rounds=2, align=8, max_bits=8,
                     seed=1), n_clusters=10)
    assert idx.packed.bitpacked
    idx_cols = dataclasses.replace(idx, packed=idx.packed.unpack())
    return idx, idx_cols


def test_search_batch_bit_identical(fitted, indexes):
    _, _, _, _, _, qs = fitted
    idx, idx_cols = indexes
    for pb in prefix_settings(idx.packed.layout):
        ids_p, d_p = idx.search_batch(qs, k=10, nprobe=6, prefix_bits=pb)
        ids_c, d_c = idx_cols.search_batch(qs, k=10, nprobe=6,
                                           prefix_bits=pb)
        np.testing.assert_array_equal(np.asarray(ids_p), np.asarray(ids_c))
        np.testing.assert_array_equal(np.asarray(d_p), np.asarray(d_c))


def test_search_multistage_bit_identical(fitted, indexes):
    _, _, _, _, _, qs = fitted
    idx, idx_cols = indexes
    i_p, d_p, st_p = idx.search_multistage(qs[0], k=10, nprobe=6)
    i_c, d_c, st_c = idx_cols.search_multistage(qs[0], k=10, nprobe=6)
    np.testing.assert_array_equal(np.asarray(i_p), np.asarray(i_c))
    np.testing.assert_array_equal(np.asarray(d_p), np.asarray(d_c))
    assert st_p.bits_accessed == st_c.bits_accessed


def test_recall_at_10_equal(fitted, indexes):
    """Acceptance: packed search_batch recall@10 == unpacked recall@10."""
    _, x, _, _, _, qs = fitted
    idx, idx_cols = indexes
    ids_p, _ = idx.search_batch(qs, k=10, nprobe=8)
    ids_c, _ = idx_cols.search_batch(qs, k=10, nprobe=8)
    xj = jnp.asarray(x)
    rec_p = rec_c = 0.0
    for j in range(qs.shape[0]):
        gt = set(np.asarray(
            brute_force_topk(xj, jnp.asarray(qs[j]), 10)[0]).tolist())
        rec_p += len(gt & set(np.asarray(ids_p[j]).tolist())) / 10.0
        rec_c += len(gt & set(np.asarray(ids_c[j]).tolist())) / 10.0
    assert rec_p == rec_c


def test_space_budget_acceptance(fitted):
    """Acceptance: measured code-buffer nbytes <= 1.05 x the exact
    bitstring budget ceil(sum_s cols_s*bits_s*N / 8) (the plan's
    64-aligned segments make rows word-aligned on the real benchmark;
    here we allow the per-row padding the format defines)."""
    _, _, _, qp, qc, _ = fitted
    lay = qp.layout
    exact = -(-lay.total_code_bits * qp.n // 8)      # ceil(bits/8)
    measured = qp.code_nbytes
    # per-row padding to whole uint32 words is the only slack
    assert measured == qp.n * lay.n_words * 4
    assert measured <= exact + qp.n * 4              # < one word per row
    if lay.total_code_bits % 32 == 0 and lay.total_code_bits > 0:
        assert measured == exact
    # and packing is a strict win vs the widest-dtype column buffer
    if lay.n_segments:
        assert measured <= qc.code_nbytes
