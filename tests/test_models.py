"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import (ModelConfig, decode_step, forward, init_params,
                          logits_fn)
from repro.train import AdamWConfig, adamw_init, make_train_step


def make_batch(cfg: ModelConfig, b=2, s=16, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.family == "audio":
        toks = jax.random.randint(key, (b, s, cfg.n_codebooks), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            key, (b, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_decode(arch):
    cfg = get_smoke_config(arch)
    params, spec = init_params(jax.random.PRNGKey(0), cfg)
    jax.tree_util.tree_map(lambda a, b: None, params, spec)  # specs mirror
    batch = make_batch(cfg)
    h, caches = forward(params, cfg, batch["tokens"],
                        img_embeds=batch.get("img_embeds"),
                        collect_cache=True, cache_max_seq=24)
    logits = logits_fn(params, cfg, h)
    assert h.shape[:2] == batch["tokens"].shape[:2]
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any()), arch
    tok = batch["tokens"][:, -1]
    lg, caches = decode_step(params, cfg, tok, 16, caches,
                             img_embeds=batch.get("img_embeds"))
    assert not bool(jnp.isnan(lg.astype(jnp.float32)).any()), arch
    assert lg.shape[-1] == cfg.vocab_size


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = adamw_init(params, opt)
    step = jax.jit(make_train_step(cfg, opt))
    batch = make_batch(cfg)
    params, state, m = step(params, state, batch)
    loss = float(m["loss"])
    assert np.isfinite(loss) and loss > 0, arch
    gn = float(m["grad_norm"])
    assert np.isfinite(gn) and gn > 0, arch


def test_decode_matches_forward_teacher_forcing():
    """decode_step over a prefix must reproduce forward()'s next-token
    logits (cache correctness) for an attention family."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("granite-20b")
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    h_full, _ = forward(params, cfg, toks)
    want = logits_fn(params, cfg, h_full)[:, -1]      # predict tok 12
    # prefill 11 tokens, then decode token 11 at pos 11
    _, caches = forward(params, cfg, toks[:, :11], collect_cache=True,
                        cache_max_seq=16)
    got, _ = decode_step(params, cfg, toks[:, 11], 11, caches)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.1, atol=0.15)


def test_ssm_decode_matches_forward():
    cfg = get_smoke_config("falcon-mamba-7b")
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    h_full, _ = forward(params, cfg, toks)
    want = logits_fn(params, cfg, h_full)[:, -1]
    _, caches = forward(params, cfg, toks[:, :11], collect_cache=True)
    got, _ = decode_step(params, cfg, toks[:, 11], 11, caches)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.1, atol=0.15)


def test_quantized_cache_close_to_bf16():
    cfg = get_smoke_config("qwen3-32b")
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    _, c_bf = forward(params, cfg, toks, collect_cache=True,
                      cache_max_seq=16)
    _, c_q8 = forward(params, cfg, toks, collect_cache=True,
                      cache_max_seq=16, cache_bits=8)
    lg_bf, _ = decode_step(params, cfg, toks[:, -1], 12, c_bf)
    lg_q8, _ = decode_step(params, cfg, toks[:, -1], 12, c_q8)
    a, b = np.asarray(lg_bf, np.float32), np.asarray(lg_q8, np.float32)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 0.05, rel


def test_packed_q4_cache_halves_codes_and_decodes():
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("granite-20b")
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    _, c8 = forward(params, cfg, toks, collect_cache=True,
                    cache_max_seq=16, cache_bits=8)
    _, c4 = forward(params, cfg, toks, collect_cache=True,
                    cache_max_seq=16, cache_bits=4)
    # bit-exact storage: a 4-bit page row is half the words of an 8-bit one
    assert c4.kv.k_words.shape[-1] * 2 == c8.kv.k_words.shape[-1]
    assert c4.kv.k_words.dtype == np.uint32
    lg8, _ = decode_step(params, cfg, toks[:, -1], 12, c8)
    lg4, _ = decode_step(params, cfg, toks[:, -1], 12, c4)
    a, b = np.asarray(lg8, np.float32), np.asarray(lg4, np.float32)
    assert np.isfinite(b).all()
    # 4-bit is coarser but must stay in the same class
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 0.5, rel


def test_q2_cache_decodes_finite():
    # the old byte path silently read bits=2 as 8-bit garbage; the
    # WordLayout path must decode it for real
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("granite-20b")
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    _, c2 = forward(params, cfg, toks, collect_cache=True,
                    cache_max_seq=16, cache_bits=2)
    assert c2.kv.k_words.shape[-1] * 16 == cfg.hd, c2.kv.k_words.shape
    lg2, _ = decode_step(params, cfg, toks[:, -1], 12, c2)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()
