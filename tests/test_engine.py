"""AnnEngine serving tests: parity with the direct batched path, mixed
dispatch-group bucketing, batching-policy accounting, admission
validation, lifecycle (stop fails the backlog with EngineClosed), live
write admission, and the empty-cluster / nprobe edge cases."""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.core.saq import SAQConfig
from repro.ivf import ClusterFullError, IVFIndex
from repro.serve import AnnEngine, BatchPolicy, EngineClosed
from conftest import decaying_data


@pytest.fixture(scope="module")
def built():
    x = decaying_data(2500, 32, alpha=0.7, seed=3)
    idx = IVFIndex.build(
        x, SAQConfig(avg_bits=4, rounds=2, align=8, max_bits=9),
        n_clusters=12)
    return x, idx


def test_engine_parity_vs_direct(built):
    """Engine results come back in submission order and equal the direct
    device-resident batched call row-for-row."""
    _, idx = built
    qs = decaying_data(16, 32, alpha=0.7, seed=11)
    with AnnEngine(idx, BatchPolicy(max_batch=8, max_wait_us=2000)) as eng:
        ids, dists = eng.search_many(qs, k=10, nprobe=6)
    ref_ids, ref_d = idx.search_batch(qs, k=10, nprobe=6)
    np.testing.assert_array_equal(ids, np.asarray(ref_ids))
    np.testing.assert_allclose(dists, np.asarray(ref_d), rtol=1e-6)


def test_engine_mixed_k_nprobe_bucketing(built):
    """Interleaved requests with different (k, nprobe, prefix_bits) land
    in separate dispatch groups and each matches its per-query search."""
    _, idx = built
    qs = decaying_data(12, 32, alpha=0.7, seed=21)
    pb = tuple(max(1, s.bits // 2) for s in idx.plan.stored_segments)
    specs = [
        dict(k=5, nprobe=4),
        dict(k=10, nprobe=6),
        dict(k=3, nprobe=6, prefix_bits=pb),
    ]
    with AnnEngine(idx, BatchPolicy(max_batch=16, max_wait_us=5000)) as eng:
        futs = [(eng.submit(q, **specs[i % 3]), specs[i % 3])
                for i, q in enumerate(qs)]
        results = [(f.result(timeout=60), s) for f, s in futs]
    for i, ((ids, dists), spec) in enumerate(results):
        ref_i, ref_d = idx.search(qs[i], **spec)
        np.testing.assert_array_equal(ids, np.asarray(ref_i))
        np.testing.assert_allclose(dists, np.asarray(ref_d), rtol=1e-6)


def test_engine_padding_and_chunking_stats(built):
    """Groups pad to the policy's static shapes; oversized groups chunk
    at the largest shape; the stats account for every dispatched row."""
    _, idx = built
    qs = decaying_data(11, 32, alpha=0.7, seed=31)
    policy = BatchPolicy(max_batch=16, max_wait_us=50_000,
                         batch_shapes=(1, 2, 4))
    with AnnEngine(idx, policy) as eng:
        ids, _ = eng.search_many(qs, k=5, nprobe=4)
        st = eng.stats
    ref_ids, _ = idx.search_batch(qs, k=5, nprobe=4)
    np.testing.assert_array_equal(ids, np.asarray(ref_ids))
    assert st.completed == 11 and st.failed == 0
    # 11 rows through shapes {1,2,4}: every dispatch is 4/2/1 wide
    assert st.dispatched_rows >= 11
    assert st.padded_rows == st.dispatched_rows - 11
    assert 0.0 < st.occupancy <= 1.0
    assert st.dispatches >= 3     # 11 > max shape forces chunking


def test_batch_policy_pad_to():
    p = BatchPolicy(max_batch=64, batch_shapes=(1, 2, 4, 8))
    assert [p.pad_to(n) for n in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]
    # beyond the largest static shape pad_to must raise, not hand back
    # a pad target smaller than n (callers chunk at batch_shapes[-1])
    with pytest.raises(ValueError, match="largest static shape"):
        p.pad_to(9)
    with pytest.raises(ValueError):
        p.pad_to(0)
    with pytest.raises(ValueError):
        BatchPolicy(max_batch=0)
    with pytest.raises(ValueError):
        BatchPolicy(batch_shapes=())
    with pytest.raises(ValueError):
        BatchPolicy(cluster_major_from=0)


def test_batch_policy_cluster_major_threshold():
    p = BatchPolicy(batch_shapes=(1, 4, 16), cluster_major_from=8)
    assert [p.cluster_major(s) for s in (1, 4, 16)] == [False, False, True]
    off = BatchPolicy(cluster_major_from=None)
    assert not any(off.cluster_major(s) for s in off.batch_shapes)


def test_engine_cluster_major_dispatch_parity(built):
    """With the cluster-major layout forced for EVERY dispatch shape,
    engine results stay bit-identical to the direct (gathered) batched
    call — the layouts share one slab-scan body."""
    _, idx = built
    qs = decaying_data(10, 32, alpha=0.7, seed=71)
    policy = BatchPolicy(max_batch=8, max_wait_us=2000,
                         batch_shapes=(1, 2, 4, 8), cluster_major_from=1)
    with AnnEngine(idx, policy) as eng:
        ids, dists = eng.search_many(qs, k=10, nprobe=6)
    ref_ids, ref_d = idx.search_batch(qs, k=10, nprobe=6)
    np.testing.assert_array_equal(ids, np.asarray(ref_ids))
    np.testing.assert_array_equal(dists.view(np.uint32),
                                  np.asarray(ref_d).view(np.uint32))


def test_engine_failed_dispatch_counts_rows(built):
    """A dispatch whose search_batch raises must still count in
    dispatches/dispatched_rows/padded_rows (it occupied the device) and
    bump failed_dispatches — otherwise occupancy silently overstates
    healthy traffic."""
    _, idx = built

    class Exploding:
        """Index proxy whose batched search always raises."""

        def __init__(self, inner):
            self._inner = inner
            self.dim = inner.dim
            self._validate_k = inner._validate_k

        def search_batch(self, *a, **kw):
            raise RuntimeError("boom")

    qs = decaying_data(3, 32, alpha=0.7, seed=81)
    policy = BatchPolicy(max_batch=4, max_wait_us=50_000,
                         batch_shapes=(1, 2, 4))
    with AnnEngine(Exploding(idx), policy) as eng:
        futs = [eng.submit(q, k=5, nprobe=4) for q in qs]
        errs = [pytest.raises(RuntimeError, f.result, timeout=60)
                for f in futs]
    assert len(errs) == 3
    st = eng.stats
    assert st.failed == 3 and st.completed == 0
    assert st.failed_dispatches >= 1
    assert st.dispatches == st.failed_dispatches
    assert st.dispatched_rows >= 3            # failed rows ARE counted
    assert st.padded_rows == st.dispatched_rows - 3
    assert 0.0 < st.occupancy <= 1.0


def test_engine_accuracy_tiers(built):
    """Accuracy tiers: the exact tier is bit-identical to the direct
    single-phase batched call, every named tier buckets separately at
    admission, and the per-tier counters (requests / dispatched rows /
    refine survivor budgets) account for every dispatch including
    padding."""
    _, idx = built
    from repro.serve import DEFAULT_TIERS
    qs = decaying_data(12, 32, alpha=0.7, seed=91)
    policy = BatchPolicy(max_batch=16, max_wait_us=50_000,
                         batch_shapes=(1, 2, 4))
    with AnnEngine(idx, policy) as eng:
        futs = []
        for tier in ("exact", "balanced", "cheap", None):
            futs.append([eng.submit(q, k=10, nprobe=6, tier=tier)
                         for q in qs])
        res = [[f.result(timeout=60) for f in fs] for fs in futs]
        st = eng.stats
    # exact tier (and tier=None) == direct single-phase, bit for bit
    ref_i, ref_d = idx.search_batch(qs, k=10, nprobe=6)
    for tier_res in (res[0], res[3]):
        np.testing.assert_array_equal(
            np.stack([i for i, _ in tier_res]), np.asarray(ref_i))
        np.testing.assert_array_equal(
            np.stack([d for _, d in tier_res]).view(np.uint32),
            np.asarray(ref_d).view(np.uint32))
    # named tiers == direct refined call, row for row
    for tier, tier_res in (("balanced", res[1]), ("cheap", res[2])):
        ti, td = idx.search_batch(qs, k=10, nprobe=6,
                                  refine=DEFAULT_TIERS[tier])
        np.testing.assert_array_equal(
            np.stack([i for i, _ in tier_res]), np.asarray(ti))
        np.testing.assert_allclose(
            np.stack([d for _, d in tier_res]), np.asarray(td),
            rtol=1e-6)
    # per-tier accounting: tier=None folds into the "exact" class
    assert st.tier_requests == {"exact": 24, "balanced": 12, "cheap": 12}
    assert set(st.tier_dispatched_rows) == {"exact", "balanced", "cheap"}
    assert st.tier_dispatched_rows["exact"] >= 24
    assert sum(st.tier_dispatched_rows.values()) == st.dispatched_rows
    # survivor budgets: rows * k_refine for refined tiers, 0 for exact
    l_max = int(idx.ids.shape[1])
    cap = 6 * l_max
    assert st.tier_refine_survivors["exact"] == 0
    for tier in ("balanced", "cheap"):
        k_ref = DEFAULT_TIERS[tier].k_refine(10, cap)
        assert (st.tier_refine_survivors[tier]
                == st.tier_dispatched_rows[tier] * k_ref)


def test_engine_tier_validation_and_stats_isolation(built):
    """Unknown tiers are rejected at admission (before any queueing);
    custom tier maps replace the defaults; stats snapshots are deep
    copies that later traffic cannot mutate."""
    _, idx = built
    from repro.ivf import RefineSpec
    q = decaying_data(1, 32, alpha=0.7, seed=92)[0]
    with AnnEngine(idx) as eng:
        with pytest.raises(ValueError, match="tier"):
            eng.submit(q, k=5, nprobe=4, tier="no-such-tier")
        ids, _ = eng.search(q, k=5, nprobe=4, tier="cheap")
        assert ids.shape == (5,)
        snap = eng.stats
        eng.search(q, k=5, nprobe=4, tier="cheap")
        assert snap.tier_requests == {"cheap": 1}   # frozen snapshot
        assert eng.stats.tier_requests == {"cheap": 2}
    custom = BatchPolicy(tiers={"only": RefineSpec(coarse_prefix=1)})
    with AnnEngine(idx, custom) as eng2:
        with pytest.raises(ValueError, match="only"):
            eng2.submit(q, k=5, nprobe=4, tier="balanced")
        ids2, _ = eng2.search(q, k=5, nprobe=4, tier="only")
        assert ids2.shape == (5,)
    with pytest.raises(ValueError):
        BatchPolicy(tiers={"": RefineSpec()})
    with pytest.raises(ValueError):
        BatchPolicy(tiers={"x": "not-a-spec"})


def test_engine_warmup_tiers(built):
    """warmup(tiers=...) pre-compiles each tier's program per shape and
    records the dispatches without touching request counters."""
    _, idx = built
    policy = BatchPolicy(max_batch=8, max_wait_us=2000,
                         batch_shapes=(1, 4))
    with AnnEngine(idx, policy) as eng:
        eng.warmup(k=10, nprobe=6, tiers=("exact", "balanced", None))
        st = eng.stats
        assert st.submitted == 0 and st.tier_requests == {}
        qs = decaying_data(4, 32, alpha=0.7, seed=93)
        ids, _ = eng.search_many(qs, k=10, nprobe=6, tier="balanced")
    assert ids.shape == (4, 10)


def test_engine_search_many_empty(built):
    """search_many([]) returns empty (0, k) arrays instead of crashing
    on np.stack of an empty list."""
    _, idx = built
    with AnnEngine(idx) as eng:
        ids, dists = eng.search_many([], k=7, nprobe=4)
        st = eng.stats
    assert ids.shape == (0, 7) and dists.shape == (0, 7)
    assert ids.dtype == np.int32 and dists.dtype == np.float32
    assert st.submitted == 0 and st.dispatches == 0


def test_batch_policy_probe_budget():
    p = BatchPolicy(probe_budget=4)
    assert p.probe_budget == 4
    assert BatchPolicy(probe_budget=0).probe_budget == 0      # disabled
    assert BatchPolicy().probe_budget is None                 # auto
    with pytest.raises(ValueError, match="probe_budget"):
        BatchPolicy(probe_budget=-1)


def test_engine_admission_validation(built):
    _, idx = built
    q = decaying_data(1, 32, alpha=0.7, seed=41)[0]
    with AnnEngine(idx) as eng:
        with pytest.raises(ValueError):       # k beyond candidate capacity
            eng.submit(q, k=10 ** 6, nprobe=1)
        with pytest.raises(ValueError):       # wrong query dim
            eng.submit(q[:7])
        # the engine keeps serving after rejected admissions
        ids, _ = eng.search(q, k=5, nprobe=4)
        assert ids.shape == (5,)


def test_engine_lifecycle(built):
    _, idx = built
    q = decaying_data(1, 32, alpha=0.7, seed=51)[0]
    eng = AnnEngine(idx)
    with pytest.raises(RuntimeError):         # not started (EngineClosed
        eng.submit(q)                         # subclasses RuntimeError)
    eng.start()
    ids, dists = eng.search(q, k=5, nprobe=4)
    assert ids.shape == (5,) and dists.shape == (5,)
    eng.stop()
    with pytest.raises(EngineClosed):         # stopped: closed admission
        eng.submit(q)
    # restartable after stop
    eng.start()
    ids2, _ = eng.search(q, k=5, nprobe=4)
    np.testing.assert_array_equal(ids2, ids)
    eng.stop()


class _Blocking:
    """Index proxy whose batched search blocks until released — pins one
    request in-flight so requests behind it are provably queued."""

    def __init__(self, inner):
        self._inner = inner
        self.started = threading.Event()
        self.release = threading.Event()

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def search_batch(self, *a, **kw):
        self.started.set()
        assert self.release.wait(timeout=120)
        return self._inner.search_batch(*a, **kw)


def test_engine_stop_fails_backlog_with_engine_closed(built):
    """stop() must FAIL futures still queued at shutdown (documented
    EngineClosed, counted in stats.closed_requests) instead of draining
    them — the old drain could hang stop() and every pending .result()
    behind a wedged dispatch. The in-flight request still completes."""
    _, idx = built
    qs = decaying_data(5, 32, alpha=0.7, seed=52)
    blk = _Blocking(idx)
    eng = AnnEngine(blk, BatchPolicy(max_wait_us=0)).start()
    inflight = eng.submit(qs[0], k=5, nprobe=4)
    assert blk.started.wait(timeout=60)       # first request is mid-scan
    queued = [eng.submit(q, k=5, nprobe=4) for q in qs[1:]]
    stopper = threading.Thread(target=eng.stop)
    stopper.start()
    time.sleep(0.05)                          # stop() is now waiting
    blk.release.set()                         # unwedge the dispatch
    stopper.join(timeout=60)
    assert not stopper.is_alive()             # stop() returned: no hang
    ids, _ = inflight.result(timeout=60)      # in-flight work completed
    assert ids.shape == (5,)
    for f in queued:                          # backlog failed, not hung
        with pytest.raises(EngineClosed):
            f.result(timeout=60)
    st = eng.stats
    assert st.closed_requests == len(queued)
    assert st.failed >= len(queued)
    with pytest.raises(EngineClosed):         # submit-after-stop
        eng.submit(qs[0])
    assert eng.stats.closed_requests == len(queued)  # rejected, not closed


def test_engine_stop_idempotent_no_backlog(built):
    _, idx = built
    eng = AnnEngine(idx).start()
    eng.stop()
    eng.stop()                                # second stop is a no-op
    assert eng.stats.closed_requests == 0


def test_engine_add_remove_admission(built):
    """Engine write admission: add is immediately searchable, remove
    immediately filtered, with write counters; search keeps serving
    throughout (no dispatch pause)."""
    _, idx = built
    idx = dataclasses.replace(idx, live=None)  # own live state
    qs = decaying_data(4, 32, alpha=0.7, seed=53)
    with AnnEngine(idx, BatchPolicy(max_wait_us=0)) as eng:
        v = decaying_data(3, 32, alpha=0.7, seed=54)
        new_ids = eng.add(v)
        ids, _ = eng.search(v[0], k=10, nprobe=idx.n_clusters)
        assert int(new_ids[0]) in ids          # immediately searchable
        eng.remove([int(new_ids[0])])
        ids2, _ = eng.search(v[0], k=10, nprobe=idx.n_clusters)
        assert int(new_ids[0]) not in ids2     # immediately filtered
        eng.search_many(qs, k=5, nprobe=6)    # reads still fine
        st = eng.stats
    assert st.adds == 3 and st.removes == 1 and st.rejected_adds == 0
    assert not idx.live.compacting            # stop() stopped the compactor


def test_engine_add_full_cluster_compaction_disabled_rejects(built):
    """With compaction disabled an add hitting a full delta buffer is
    REJECTED (ClusterFullError surfaced + counted), never dropped; with
    compaction enabled the engine folds synchronously and admits."""
    _, idx = built
    idx = dataclasses.replace(idx, live=None)
    idx.enable_live(l_delta=1)
    v = decaying_data(40, 32, alpha=0.7, seed=55)
    with AnnEngine(idx, compaction=False) as eng:
        with pytest.raises(ClusterFullError):
            eng.add(v)                        # 40 rows over 12 1-slot slabs
        st = eng.stats
        assert st.rejected_adds == 40 and st.adds == 0
        assert idx.live.n_delta_rows == 0     # atomic: nothing admitted
        assert not idx.live.compacting        # policy respected
    idx2 = dataclasses.replace(idx, live=None)
    idx2.enable_live(l_delta=1)
    with AnnEngine(idx2, compaction=True) as eng2:
        for lo in range(0, 12, 1):            # 1-row batches always fit
            eng2.add(v[lo:lo + 1])            # after a synchronous fold
        st2 = eng2.stats
    assert st2.adds == 12 and st2.rejected_adds == 0
    assert st2.compactions == idx2.live.compactions


def test_k_exceeding_candidates_raises(built):
    _, idx = built
    qs = decaying_data(2, 32, alpha=0.7, seed=61)
    l_max = int(idx.ids.shape[1])
    with pytest.raises(ValueError, match="candidate capacity"):
        idx.search_batch(qs, k=l_max + 1, nprobe=1)
    with pytest.raises(ValueError):
        idx.search_batch(qs, k=0, nprobe=4)
    with pytest.raises(ValueError):
        idx.search_batch(qs, k=5, nprobe=0)
    # valid boundary: k == min(nprobe, C) * L works
    ids, _ = idx.search_batch(qs, k=l_max, nprobe=1)
    assert ids.shape == (2, l_max)


def test_empty_cluster_and_nprobe_gt_c_edges():
    """Duplicate-blob data leaves clusters empty after the final kmeans
    assignment; searches probing them (and nprobe > C) stay correct."""
    rng = np.random.default_rng(7)
    blobs = rng.standard_normal((3, 16)).astype(np.float32) * 4.0
    x = np.repeat(blobs, 12, axis=0)          # 36 rows, 3 distinct values
    idx = IVFIndex.build(
        x, SAQConfig(avg_bits=4, rounds=2, align=8, max_bits=9),
        n_clusters=8)
    counts = np.asarray(idx.counts)
    assert (counts == 0).any(), counts        # the edge is actually hit
    q = blobs[0] + 0.01
    ids, dists = idx.search(q, k=5, nprobe=idx.n_clusters)
    assert (np.asarray(ids) >= 0).all()       # padding never leaks out
    assert np.isfinite(np.asarray(dists)).all()
    # nprobe far beyond C clamps and matches the exact-C probe search
    ids2, d2 = idx.search(q, k=5, nprobe=10 ** 4)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids2))
    with AnnEngine(idx) as eng:
        e_ids, _ = eng.search(q, k=5, nprobe=10 ** 4)
    np.testing.assert_array_equal(e_ids, np.asarray(ids))
