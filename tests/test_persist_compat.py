"""Persistence compatibility: golden v1/v2 fixture directories load and
auto-repack to the bit-packed v3 in-memory form, a save -> load -> save
cycle is byte-stable, and corrupt/truncated word buffers raise a clear
error instead of returning garbage results."""
import dataclasses
import filecmp
import json
import os
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.saq import SAQConfig
from repro.ivf import IVFIndex, load_index, save_index
from repro.ivf.persist import FORMAT_VERSION, CorruptIndexError
from conftest import decaying_data


@pytest.fixture(scope="module")
def index():
    x = decaying_data(600, 32, alpha=0.7, seed=5)
    return x, IVFIndex.build(
        x, SAQConfig(avg_bits=4, rounds=2, align=8, max_bits=8),
        n_clusters=8)


def _write_fixture(index, path, fmt):
    """Emit a golden legacy directory exactly as the old writers did."""
    os.makedirs(path)
    saq = index.saq
    lay = index.packed.layout
    cols = index.packed.unpack()     # legacy formats store columns
    arrays = {
        "centroids": index.centroids, "ids": index.ids,
        "counts": index.counts,
        "o_norm_total": cols.o_norm_sq_total,
        "g_proj": index.g_proj, "variances": saq.variances,
    }
    if fmt == 2:
        arrays |= {"codes": cols.codes, "factors": cols.factors,
                   "g_rot": index.g_rot}
    else:   # v1: per-segment arrays
        for s in range(lay.n_segments):
            lo, hi = lay.col_bounds(s)
            arrays[f"seg{s}_codes"] = cols.codes[..., lo:hi]
            arrays[f"seg{s}_vmax"] = cols.factors[..., s, 0]
            arrays[f"seg{s}_rescale"] = cols.factors[..., s, 1]
            arrays[f"seg{s}_grot"] = index.g_rot[..., lo:hi]
    for s, rot in enumerate(saq.rotations):
        arrays[f"seg{s}_rotation"] = rot
    if saq.pca is not None:
        arrays |= {"pca_mean": saq.pca.mean,
                   "pca_components": saq.pca.components,
                   "pca_variances": saq.pca.variances}
    for name, a in arrays.items():
        np.save(os.path.join(path, f"{name}.npy"), np.asarray(a))
    manifest = {
        "format": fmt,
        "config": dataclasses.asdict(saq.config) | {"plan": None},
        "plan": [[s.start, s.stop, s.bits] for s in saq.plan.segments],
        "dim": saq.plan.dim,
        "n_segments": lay.n_segments,
        "has_pca": saq.pca is not None,
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


@pytest.mark.parametrize("fmt", [1, 2])
def test_golden_legacy_formats_load_and_repack(tmp_path, index, fmt):
    x, idx = index
    gold = str(tmp_path / f"v{fmt}")
    _write_fixture(idx, gold, fmt)
    loaded = load_index(gold)
    # auto-repacked to the bit-packed in-memory form
    assert loaded.packed.bitpacked
    assert loaded.packed.codes.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(loaded.packed.codes),
                                  np.asarray(idx.packed.codes))
    # identical search results through the repacked buffer
    qs = decaying_data(3, 32, alpha=0.7, seed=50)
    ids_a, d_a = idx.search_batch(qs, k=5, nprobe=4)
    ids_b, d_b = loaded.search_batch(qs, k=5, nprobe=4)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    np.testing.assert_array_equal(np.asarray(d_a), np.asarray(d_b))
    # and saving the loaded index upgrades it to v3 on disk
    up = str(tmp_path / f"v{fmt}_resaved")
    save_index(loaded, up)
    with open(os.path.join(up, "manifest.json")) as f:
        m = json.load(f)
    assert m["format"] == FORMAT_VERSION and m["bitpacked"]


def test_save_load_save_byte_stable(tmp_path, index):
    _, idx = index
    p1, p2 = str(tmp_path / "a"), str(tmp_path / "b")
    save_index(idx, p1)
    save_index(load_index(p1), p2)
    files = sorted(os.listdir(p1))
    assert files == sorted(os.listdir(p2))
    _, mismatch, errors = filecmp.cmpfiles(p1, p2, files, shallow=False)
    assert not mismatch and not errors, (mismatch, errors)


def test_overwrite_crash_keeps_a_loadable_copy(tmp_path, index):
    """An overwriting save that dies at ANY step of the swap must leave
    a loadable index at `path` or `path.bak` — the old rmtree(path) ->
    replace(tmp, path) sequence had a window that destroyed the only
    copy. Every os.replace / shutil.rmtree call of the swap is failed
    in turn and a full save -> load round-trip must still succeed from
    whatever survived."""
    import repro.ivf.persist as persist
    _, idx = index
    path = str(tmp_path / "idx")
    save_index(idx, path)
    ref_ids = np.asarray(idx.ids)

    def check_recoverable():
        # load_index(path) itself must recover — it falls back to the
        # .bak survivor when the swap died with `path` missing
        loaded = load_index(path)
        np.testing.assert_array_equal(np.asarray(loaded.ids), ref_ids)

    real_replace, real_rmtree = os.replace, shutil.rmtree
    # Every overwriting save starts with a stale .bak parked next to
    # the index (as a crashed earlier save would leave) so the swap
    # makes exactly these destructive calls, failed one per iteration:
    #   rmtree #1  stale .bak removal      (path still intact)
    #   replace #1 path -> .bak            (path still intact)
    #   replace #2 .tmp -> path            (old index survives at .bak)
    #   rmtree #2  .bak cleanup            (new index already at path)
    # (.tmp staging calls are exempt: they precede any destructive
    # step, so crashing there trivially leaves `path` intact)
    for prim, fail_at in (("rmtree", 1), ("replace", 1),
                          ("replace", 2), ("rmtree", 2)):
        os.makedirs(path + ".bak", exist_ok=True)   # stale leftover
        calls = {"n": 0}

        def flaky(src, *a, _prim=prim, _fail=fail_at, **kw):
            real = real_replace if _prim == "replace" else real_rmtree
            if _prim == "rmtree" and str(src).endswith(".tmp"):
                return real(src, *a, **kw)
            calls["n"] += 1
            if calls["n"] == _fail:
                raise OSError(f"injected crash: {_prim} #{_fail}")
            return real(src, *a, **kw)

        try:
            if prim == "replace":
                persist.os.replace = flaky
            else:
                persist.shutil.rmtree = flaky
            with pytest.raises(OSError, match="injected crash"):
                save_index(idx, path)
        finally:
            persist.os.replace = real_replace
            persist.shutil.rmtree = real_rmtree
        check_recoverable()
        # the next (uninterrupted) save must self-recover: stale
        # .tmp/.bak cleaned up, fresh loadable index in place (after
        # the replace #2 crash `path` is gone and the backup holds the
        # only copy — the save writes a fresh index and then drops the
        # obsolete backup)
        save_index(idx, path)
        assert not os.path.exists(path + ".bak")
        check_recoverable()


# Every on-disk state the save swap sequence (stage tmp -> rmtree stale
# bak -> rename path to bak -> rename tmp to path -> rmtree bak) can be
# killed in, as (suffix, copy) layouts: "old"/"new" are two complete but
# distinguishable saves, "partial_*" the same save with the manifest
# missing (the manifest is written LAST, so a dir without one is a
# mid-stage corpse). `expect` names the copy recovery must promote: the
# NEWEST complete one.
_CRASH_STATES = [
    # died while staging: the partial tmp is junk, path is current
    ("stage_died", [("", "old"), (".tmp", "partial_new")], "old"),
    # fully staged, died before any swap rename: tmp is the newest copy
    ("preswap_died", [("", "old"), (".tmp", "new")], "new"),
    # ... same, plus a stale backup left by an even older crash
    ("preswap_stale_bak", [("", "old"), (".tmp", "new"), (".bak", "old")],
     "new"),
    # died between parking the old copy at .bak and promoting tmp
    ("midswap_died", [(".tmp", "new"), (".bak", "old")], "new"),
    # recovery itself died mid-promote, leaving junk where path was
    ("midswap_junk_path", [("", "partial_old"), (".tmp", "new"),
                           (".bak", "old")], "new"),
    # tmp promoted-or-lost, backup holds the only complete copy
    ("bak_only", [(".bak", "old")], "old"),
    # junk at path (torn rename), backup complete
    ("junk_path_bak", [("", "partial_new"), (".bak", "old")], "old"),
    # died after promoting the new copy but before the backup cleanup
    ("postswap_died", [("", "new"), (".bak", "old")], "new"),
]


@pytest.mark.parametrize(
    "layout,expect", [(lay, exp) for _, lay, exp in _CRASH_STATES],
    ids=[name for name, _, _ in _CRASH_STATES])
def test_load_recovers_every_crash_state(tmp_path, index, layout, expect):
    """load_index must recover from EVERY intermediate state of the save
    swap: promote the newest complete copy back to `path`, clean all
    leftovers, and stay idempotent. States are constructed directly (no
    timing luck) from two distinguishable complete saves."""
    _, idx = index
    old_dir, new_dir = str(tmp_path / "src_old"), str(tmp_path / "src_new")
    save_index(idx, old_dir)
    # same index, ids offset by +1000 (padding kept at -1) — loadable
    # and trivially distinguishable from the old copy
    shifted = dataclasses.replace(
        idx, ids=jnp.where(idx.ids >= 0, idx.ids + 1000, idx.ids))
    save_index(shifted, new_dir)
    want = {"old": np.asarray(idx.ids), "new": np.asarray(shifted.ids)}

    path = str(tmp_path / "idx")
    for suffix, src in layout:
        d = path + suffix
        shutil.copytree(old_dir if src.endswith("old") else new_dir, d)
        if src.startswith("partial"):
            os.remove(os.path.join(d, "manifest.json"))

    loaded = load_index(path)
    np.testing.assert_array_equal(np.asarray(loaded.ids), want[expect])
    # leftovers cleaned: exactly `path` remains
    assert not os.path.exists(path + ".tmp")
    assert not os.path.exists(path + ".bak")
    # idempotent: a second load sees a clean state and agrees
    again = load_index(path)
    np.testing.assert_array_equal(np.asarray(again.ids), want[expect])
    # and the recovered directory accepts a fresh overwriting save
    save_index(idx, path)
    np.testing.assert_array_equal(np.asarray(load_index(path).ids),
                                  want["old"])


def test_v3_manifest_records_word_layout(tmp_path, index):
    _, idx = index
    p = str(tmp_path / "idx")
    save_index(idx, p)
    with open(os.path.join(p, "manifest.json")) as f:
        m = json.load(f)
    lay = idx.packed.layout
    assert m["n_words"] == lay.n_words
    assert m["total_code_bits"] == lay.total_code_bits
    codes = np.load(os.path.join(p, "codes.npy"))
    assert codes.dtype == np.uint32 and codes.shape[-1] == lay.n_words


def test_truncated_word_buffer_raises(tmp_path, index):
    _, idx = index
    p = str(tmp_path / "idx")
    save_index(idx, p)
    fp = os.path.join(p, "codes.npy")
    raw = open(fp, "rb").read()
    with open(fp, "wb") as f:       # chop the file mid-array
        f.write(raw[: max(64, len(raw) // 3)])
    with pytest.raises(CorruptIndexError, match="truncated or corrupted"):
        load_index(p)


def test_wrong_word_count_raises(tmp_path, index):
    _, idx = index
    p = str(tmp_path / "idx")
    save_index(idx, p)
    codes = np.load(os.path.join(p, "codes.npy"))
    np.save(os.path.join(p, "codes.npy"), codes[..., :-1])  # drop a word
    with pytest.raises(CorruptIndexError, match="words/row"):
        load_index(p)


def test_wrong_dtype_raises(tmp_path, index):
    _, idx = index
    p = str(tmp_path / "idx")
    save_index(idx, p)
    codes = np.load(os.path.join(p, "codes.npy"))
    np.save(os.path.join(p, "codes.npy"), codes.astype(np.uint16))
    with pytest.raises(CorruptIndexError, match="uint32"):
        load_index(p)


def test_v2_wrong_column_count_raises(tmp_path, index):
    _, idx = index
    gold = str(tmp_path / "v2bad")
    _write_fixture(idx, gold, 2)
    codes = np.load(os.path.join(gold, "codes.npy"))
    np.save(os.path.join(gold, "codes.npy"), codes[..., :-2])
    with pytest.raises(CorruptIndexError, match="columns"):
        load_index(gold)
