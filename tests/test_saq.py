import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.saq import SAQ, SAQConfig, fit_caq, fit_saq
from conftest import decaying_data


@pytest.fixture(scope="module")
def data():
    return decaying_data(1500, 64, alpha=0.8, seed=0)


@pytest.fixture(scope="module")
def queries():
    return decaying_data(6, 64, alpha=0.8, seed=100)


def rel_err(est, true):
    return np.abs(est - true) / np.maximum(true, 1e-9)


def test_saq_beats_caq(data, queries):
    errs = {}
    for name, q in [("caq", fit_caq(data, bits=4, rounds=4)),
                    ("saq", fit_saq(data, avg_bits=4, rounds=4, align=8,
                                    max_bits=10))]:
        qds = q.encode(data)
        e = []
        for i in range(queries.shape[0]):
            qc = q.preprocess_query(jnp.asarray(queries[i]))
            est = np.asarray(q.estimate_dist_sq(qds, qc))
            true = ((data - queries[i]) ** 2).sum(-1)
            e.append(rel_err(est, true).mean())
        errs[name] = np.mean(e)
    assert errs["saq"] < errs["caq"] * 0.8, errs


def test_saq_decode_roundtrip(data):
    saq = fit_saq(data[:200], avg_bits=8, rounds=4, align=8, max_bits=12)
    qds = saq.encode(data[:200])
    rec = np.asarray(saq.unproject(saq.decode(qds)))
    rel = np.abs(rec - data[:200]).mean() / np.abs(data[:200]).mean()
    assert rel < 0.02


def test_multistage_bound_is_lower_bound(data, queries):
    saq = fit_saq(data, avg_bits=4, rounds=4, align=8, max_bits=10)
    qds = saq.encode(data)
    q = jnp.asarray(queries[0])
    qc = saq.preprocess_query(q)
    est_full = np.asarray(saq.estimate_dist_sq(qds, qc))
    n_seg = len(qds.segments)
    for stage in range(n_seg):
        lb = np.asarray(saq.dist_bounds(qds, qc, stage, m=4.0))
        # Chebyshev bound (m=4 -> >=93.75% per segment); allow small
        # violation count
        frac_viol = float((lb > est_full + 1e-5).mean())
        assert frac_viol < 0.05, (stage, frac_viol)


def test_progressive_prefix_errors_close_to_native(data, queries):
    saq = fit_caq(data, bits=8, rounds=4)
    qds8 = saq.encode(data)
    q = jnp.asarray(queries[0])
    qc = saq.preprocess_query(q)
    true = ((data - queries[0]) ** 2).sum(-1)
    e_prefix = rel_err(np.asarray(
        saq.estimate_dist_sq(qds8, qc, prefix_bits=[4])), true).mean()
    caq4 = fit_caq(data, bits=4, rounds=4)
    qds4 = caq4.encode(data)
    qc4 = caq4.preprocess_query(q)
    e_native = rel_err(np.asarray(
        caq4.estimate_dist_sq(qds4, qc4)), true).mean()
    assert e_prefix < e_native * 2.5      # Fig 12: close, slightly larger


def test_flat_spectrum_falls_back_to_caq():
    r = np.random.default_rng(5)
    flat = r.standard_normal((800, 32)).astype(np.float32)
    saq = fit_saq(flat, avg_bits=4, rounds=2, align=8, max_bits=8)
    assert len(saq.plan.segments) <= 2
