"""Kernel & serving-policy autotuner: the persisted per-host
``TuningCache`` (fingerprint gating, loud corrupt-file rejection,
byte-stable round trip), poisoned-entry degradation, ``n_tile``
threading bit-identity through the ops shims and ``search_batch``, the
``BatchPolicy.tuned`` / ``AnnEngine(tuned=)`` resolution order, the
cache-resolved mesh probe-budget slack, and the sweep's bit-identity
gate on a synthetic operator (a config that changes results must never
become the cached winner).

The tuner's hard contract threads through every test here: a tuned
config may only change SPEED — with no cache, a mismatched cache, or a
poisoned entry, every code path must behave bit-for-bit as the
hand-tuned defaults.
"""
import json
import math
import os

import jax
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import decaying_data
from repro.core.saq import SAQConfig, fit_saq
from repro.ivf import IVFIndex
from repro.kernels import ops
from repro.serve import AnnEngine, BatchPolicy
from repro.tune.cache import (CACHE_ENV_VAR, CorruptTuningCacheError,
                              TuningCache, get_active_cache,
                              host_fingerprint, load_default_cache,
                              lookup_backend, lookup_n_tile,
                              resolve_cache, sanitize_n_tile,
                              set_active_cache, shape_key)


@pytest.fixture(autouse=True)
def _no_leaked_active_cache():
    """Every test leaves the process-global cache the way it found it
    (deactivated) — a leaked cache would silently re-tune other suites."""
    set_active_cache(None)
    yield
    set_active_cache(None)


@pytest.fixture(scope="module")
def fitted():
    x = decaying_data(400, 32, seed=21)
    saq = fit_saq(x, avg_bits=4, rounds=2, align=8, max_bits=8)
    packed = saq.encode(jnp.asarray(x))
    qs = decaying_data(8, 32, seed=22)
    qc = saq.preprocess_queries(jnp.asarray(qs))
    return saq, packed, qc


@pytest.fixture(scope="module")
def built():
    x = decaying_data(600, 32, seed=23)
    idx = IVFIndex.build(jnp.asarray(x),
                         SAQConfig(avg_bits=4, rounds=2, align=8,
                                   max_bits=8),
                         n_clusters=8, kmeans_iters=4, seed=0)
    q = np.asarray(x[:4], np.float32)
    return idx, q


def _bits(a):
    a = np.asarray(a)
    return a.view(np.uint32 if a.dtype.itemsize == 4 else np.uint64)


def _demo_cache() -> TuningCache:
    cache = TuningCache()
    cache.put("saq_scan", shape_key(n=400, nq=8, bitpacked=1),
              {"n_tile": 64}, {"time_s": 0.001})
    cache.policy = {"cluster_major_from": 16, "batch_shapes": [1, 2, 4],
                    "probe_budget": 4, "probe_budget_slack": 3}
    cache.meta = {"fast": True}
    return cache


# ---------------------------------------------------------------------------
# persistence: byte-stable round trip, loud corrupt-file rejection
# ---------------------------------------------------------------------------

def test_save_load_save_byte_stable(tmp_path):
    cache = _demo_cache()
    p1 = str(tmp_path / "a.json")
    p2 = str(tmp_path / "b.json")
    cache.save(p1)
    loaded = TuningCache.load(p1)
    assert loaded.fingerprint == cache.fingerprint
    assert loaded.policy == cache.policy
    assert loaded.get("saq_scan", shape_key(n=400, nq=8, bitpacked=1)) \
        == {"n_tile": 64}
    loaded.save(p2)
    assert open(p1, "rb").read() == open(p2, "rb").read()
    # overwrite in place is stable too (atomic replace, no append drift)
    loaded.save(p1)
    assert open(p1, "rb").read() == open(p2, "rb").read()


@pytest.mark.parametrize("payload", [
    "not json at all {",
    json.dumps([1, 2, 3]),                              # wrong top level
    json.dumps({"version": 999, "fingerprint": {}, "policy": {},
                "entries": {}}),                        # unknown version
    json.dumps({"version": 1, "fingerprint": {}, "policy": {}}),
                                                        # missing entries
    json.dumps({"version": 1, "fingerprint": "x", "policy": {},
                "entries": {}}),                        # malformed section
], ids=["torn-json", "top-level", "version", "missing", "malformed"])
def test_corrupt_cache_raises_loudly(tmp_path, payload):
    """A broken cache file is a deployment bug, not a missing
    optimization — it must raise (mirroring CorruptIndexError), never
    silently fall back to defaults."""
    p = str(tmp_path / "cache.json")
    with open(p, "w") as f:
        f.write(payload)
    with pytest.raises(CorruptTuningCacheError):
        TuningCache.load(p)


def test_truncated_cache_raises(tmp_path):
    p = str(tmp_path / "cache.json")
    _demo_cache().save(p)
    raw = open(p, "rb").read()
    with open(p, "wb") as f:
        f.write(raw[: len(raw) // 2])       # torn mid-write
    with pytest.raises(CorruptTuningCacheError):
        TuningCache.load(p)


def test_default_cache_resolution(tmp_path, monkeypatch):
    p = str(tmp_path / "cache.json")
    monkeypatch.setenv(CACHE_ENV_VAR, p)
    assert load_default_cache() is None          # absence is normal
    _demo_cache().save(p)
    assert load_default_cache() is not None
    assert resolve_cache(True) is not None       # env-var path
    with open(p, "w") as f:
        f.write("garbage")                       # breakage never is
    with pytest.raises(CorruptTuningCacheError):
        load_default_cache()
    with pytest.raises(FileNotFoundError):
        resolve_cache(str(tmp_path / "missing.json"))
    with pytest.raises(TypeError):
        resolve_cache(42)


# ---------------------------------------------------------------------------
# fingerprint gating + poisoned entries degrade to defaults
# ---------------------------------------------------------------------------

def test_fingerprint_mismatch_falls_back_to_defaults():
    cache = _demo_cache()
    cache.fingerprint = dict(cache.fingerprint,
                             device_kind="tpu-from-another-host")
    assert not cache.matches_host()
    # activation refuses it (lookups would be another machine's wins)
    assert set_active_cache(cache) is None
    assert get_active_cache() is None
    assert lookup_n_tile("saq_scan",
                         {"n": 400, "nq": 8, "bitpacked": 1}) is None
    # policy resolution falls back to the hand-tuned BatchPolicy
    assert BatchPolicy.tuned(cache) == BatchPolicy()


def test_sanitize_poisoned_n_tile():
    assert sanitize_n_tile(7) == 7
    assert sanitize_n_tile(1) == 1
    for junk in (True, False, 0, -4, "8", 3.5, None, [16]):
        assert sanitize_n_tile(junk) is None


def test_lookup_backend_drops_poisoned_strings():
    cache = TuningCache()
    dims = {"nq": 4, "p": 2, "l": 16}
    key = shape_key(**dims)
    for bogus in ("warp-speed", 17, None):
        cache.put("probe_scan", key, {"backend": bogus})
        set_active_cache(cache)
        assert lookup_backend("probe_scan", dims) is None
    # cluster-major entry offered to a gathered-only entry point: drop
    cache.put("probe_scan", key, {"backend": "xla-cluster-major"})
    assert lookup_backend("probe_scan", dims,
                          allow_cluster_major=False) is None
    assert lookup_backend("probe_scan", dims,
                          allow_cluster_major=True) \
        == "xla-cluster-major"
    cache.put("probe_scan", key, {"backend": "xla"})
    assert lookup_backend("probe_scan", dims,
                          allow_cluster_major=False) == "xla"


def test_poisoned_or_odd_n_tile_scan_bit_identical(fitted):
    """The acceptance pin: entries the sweep could never have written
    (poisoned types) AND legal-but-unusual tile sizes must leave
    ``ops.saq_scan`` results bit-identical to the no-cache default —
    row tiling only changes the grid, never any row's contraction."""
    saq, packed, qc = fitted
    key = shape_key(n=int(packed.codes.shape[0]),
                    nq=int(qc.q_rot.shape[0]),
                    bitpacked=int(packed.bitpacked))
    ref = np.asarray(ops.saq_scan(packed, qc.q_rot,
                                  q_norm_sq=qc.q_norm_sq))
    for val in (True, -4, "x", 3, 7, 10_000):
        cache = TuningCache()
        cache.put("saq_scan", key, {"n_tile": val})
        assert set_active_cache(cache) is cache
        got = np.asarray(ops.saq_scan(packed, qc.q_rot,
                                      q_norm_sq=qc.q_norm_sq))
        set_active_cache(None)
        np.testing.assert_array_equal(_bits(ref), _bits(got),
                                      err_msg=f"n_tile={val!r}")


def test_tuned_n_tile_search_batch_bit_identical(built):
    """End to end through the jit'd ``search_batch`` program on the
    Pallas parity path: a cache-resolved ``n_tile`` for the probe scan
    at the search's true static shape must not change the top-k by one
    bit. ``jax.clear_caches()`` forces the re-trace — the shim consult
    happens at trace time, so without it the cached program would
    simply be reused (the documented stale-program behavior: a missed
    speedup, never a wrong result)."""
    idx, q = built
    k, nprobe = 5, 4
    backend = "pallas-interpret"
    ids_ref, d_ref = idx.search_batch(q, k=k, nprobe=nprobe,
                                      backend=backend)
    cache = TuningCache()
    dims = {"nq": q.shape[0], "p": min(nprobe, idx.n_clusters),
            "l": int(idx.ids.shape[1])}
    cache.put("probe_scan", shape_key(**dims), {"n_tile": 3})
    assert set_active_cache(cache) is cache
    jax.clear_caches()
    ids_t, d_t = idx.search_batch(q, k=k, nprobe=nprobe, backend=backend)
    np.testing.assert_array_equal(np.asarray(ids_ref), np.asarray(ids_t))
    np.testing.assert_array_equal(_bits(d_ref), _bits(d_t))


def test_explicit_n_tile_wins_over_cache(fitted):
    """Resolution order: explicit caller value > cache > default. An
    explicit ``n_tile`` must be honored (and stay bit-identical) even
    with a conflicting active cache."""
    saq, packed, qc = fitted
    key = shape_key(n=int(packed.codes.shape[0]),
                    nq=int(qc.q_rot.shape[0]),
                    bitpacked=int(packed.bitpacked))
    cache = TuningCache()
    cache.put("saq_scan", key, {"n_tile": 128})
    assert set_active_cache(cache) is cache
    ref = np.asarray(ops.saq_scan(packed, qc.q_rot,
                                  q_norm_sq=qc.q_norm_sq))
    got = np.asarray(ops.saq_scan(packed, qc.q_rot,
                                  q_norm_sq=qc.q_norm_sq, n_tile=5))
    np.testing.assert_array_equal(_bits(ref), _bits(got))


# ---------------------------------------------------------------------------
# serving-policy resolution: BatchPolicy.tuned / AnnEngine(tuned=) / budget
# ---------------------------------------------------------------------------

def test_batch_policy_tuned_resolution():
    cache = _demo_cache()
    pol = BatchPolicy.tuned(cache)
    assert pol.cluster_major_from == 16
    assert pol.batch_shapes == (1, 2, 4)
    assert pol.probe_budget == 4
    # explicit values always win over the cache
    pol2 = BatchPolicy.tuned(cache, cluster_major_from=2,
                             batch_shapes=(1, 8))
    assert pol2.cluster_major_from == 2
    assert pol2.batch_shapes == (1, 8)
    assert pol2.probe_budget == 4           # untouched field still tuned
    # None / absent cache -> hand-tuned defaults, bit-for-bit
    assert BatchPolicy.tuned(None) == BatchPolicy()


def test_batch_policy_tuned_drops_poisoned_policy():
    cache = TuningCache()
    cache.policy = {"cluster_major_from": True, "batch_shapes": "nope",
                    "probe_budget": -2}
    assert BatchPolicy.tuned(cache) == BatchPolicy()
    cache.policy = {"batch_shapes": []}     # empty ladder is poisoned
    assert BatchPolicy.tuned(cache) == BatchPolicy()


def test_engine_tuned_argument(built):
    idx, q = built
    cache = _demo_cache()
    with pytest.raises(ValueError, match="not both"):
        AnnEngine(idx, policy=BatchPolicy(), tuned=cache)
    with AnnEngine(idx, tuned=cache) as eng:
        # the engine resolved its policy from the cache AND activated
        # it for the kernel shims' trace-time consults
        assert eng.policy.cluster_major_from == 16
        assert eng.policy.batch_shapes == (1, 2, 4)
        assert get_active_cache() is cache
        fut = eng.submit(q[0], k=5, nprobe=4)
        ids, _ = fut.result(timeout=60)
        assert len(ids) == 5


def test_probe_budget_slack_from_cache():
    from repro.ivf.distributed import (PROBE_BUDGET_SLACK,
                                       default_probe_budget)
    nprobe, shards = 16, 4
    hand = min(nprobe, math.ceil(nprobe / shards) * PROBE_BUDGET_SLACK)
    assert default_probe_budget(nprobe, shards) == hand
    cache = TuningCache()
    cache.policy = {"probe_budget_slack": 3}
    assert set_active_cache(cache) is cache
    assert default_probe_budget(nprobe, shards) \
        == min(nprobe, math.ceil(nprobe / shards) * 3)
    # explicit slack still wins; poisoned slack degrades to hand-tuned
    assert default_probe_budget(nprobe, shards, slack=1) \
        == math.ceil(nprobe / shards)
    cache.policy = {"probe_budget_slack": True}
    assert default_probe_budget(nprobe, shards) == hand


# ---------------------------------------------------------------------------
# registry + sweep: default-first enumeration, bit-identity gate
# ---------------------------------------------------------------------------

def test_registry_registers_scan_operators():
    from repro.tune import registry
    expected = {"saq_scan", "probe_scan", "cluster_scan", "refine_scan",
                "two_phase_search", "multistage_scan", "attend_scan"}
    assert expected <= set(registry.OPERATORS)
    for name in expected:
        op = registry.OPERATORS[name]
        cfgs = list(op.configs(fast=True))
        assert cfgs[0] == op.default_config      # reference runs first
        assert all(c == op.default_config or c != cfgs[0]
                   for c in cfgs[1:])
        # every kernel-backed operator exposes at least one work metric
        if name in ("saq_scan", "probe_scan", "cluster_scan",
                    "refine_scan", "attend_scan"):
            assert op.metrics, f"{name} has no registered metrics"
    # the attend op sweeps the streaming block size and the backend
    assert set(registry.OPERATORS["attend_scan"].config_space) \
        == {"s_block", "backend"}


def test_sweep_bit_identity_gate_rejects_wrong_results():
    """A synthetic operator where one config is FASTER but returns
    different results: the sweep must record it (flagged) and keep the
    default as the winner — speed never buys a results change."""
    from repro.tune.autotune import tune_operator
    from repro.tune.registry import Operator, Workload

    x = jnp.asarray(np.linspace(0.0, 1.0, 512, dtype=np.float32))

    def run(wl, *, mode="exact"):
        v = wl.operands["x"]
        if mode == "exact":
            return jnp.sort(v)[::-1]
        return v                     # "fast" but wrong: skips the sort

    op = Operator(
        name="toy", fn=run,
        config_space={"mode": ("exact", "wrong")},
        fast_config_space={"mode": ("exact", "wrong")},
        default_config={"mode": "exact"},
        workloads=lambda fast: [Workload(dims={"n": 512},
                                         operands={"x": x})])
    entries = tune_operator(op, fast=True, repeats=1,
                            log=lambda *a, **k: None)
    assert len(entries) == 1
    ent = entries[0]
    assert ent["shape_key"] == "n=512"
    assert ent["config"] == {"mode": "exact"}    # wrong config lost
    flagged = [m for m in ent["metrics"]["measured"]
               if m["config"] == {"mode": "wrong"} and not m.get("pruned")]
    assert flagged and flagged[0]["bit_identical"] is False


def test_sweep_accepts_bit_identical_winner_and_caches_it(tmp_path):
    """A config that IS bit-identical may win; the entry round-trips
    through the persisted cache and resolves via the shim lookup."""
    from repro.tune.autotune import tune_operator
    from repro.tune.registry import Operator, Workload

    x = jnp.asarray(decaying_data(256, 8, seed=5))

    def run(wl, *, n_tile=None):
        # row-independent reduction: any tiling is bit-identical
        return jnp.sum(wl.operands["x"] * wl.operands["x"], axis=1)

    op = Operator(
        name="rowsum", fn=run,
        config_space={"n_tile": (8, 64)},
        fast_config_space={"n_tile": (8, 64)},
        default_config={"n_tile": None},
        workloads=lambda fast: [Workload(dims={"n": 256},
                                         operands={"x": x})])
    entries = tune_operator(op, fast=True, repeats=1,
                            log=lambda *a, **k: None)
    cache = TuningCache()
    cache.put("rowsum", entries[0]["shape_key"], entries[0]["config"],
              entries[0]["metrics"])
    p = str(tmp_path / "cache.json")
    cache.save(p)
    loaded = TuningCache.load(p)
    assert set_active_cache(loaded) is loaded
    cfg = loaded.get("rowsum", "n=256")
    assert cfg is not None and set(cfg) == {"n_tile"}
    # the winner is a member of the swept grid (or the default)
    assert cfg["n_tile"] in (None, 8, 64)
