"""Property-based tests (hypothesis) for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.caq import caq_encode, caq_prefix
from repro.core.lvq import lvq_symmetric_init
from repro.core.plan import plan_error, search_plan
from repro.core.rotation import fwht

finite_f32 = st.floats(-1e3, 1e3, allow_nan=False, allow_infinity=False,
                       width=32)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.integers(2, 24), st.integers(1, 8),
       st.integers(0, 2 ** 31 - 1))
def test_symmetric_grid_roundtrip_bound(n, d, bits, seed):
    x = np.random.default_rng(seed).uniform(-10, 10, (n, d)) \
        .astype(np.float32)
    g = lvq_symmetric_init(x, bits)
    err = np.abs(np.asarray(g.decode()) - x)
    delta = np.asarray(g.delta)
    assert (err <= delta[:, None] * 0.5 + 1e-4).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 10), st.integers(4, 16), st.integers(2, 6),
       st.integers(0, 2 ** 31 - 1))
def test_adjustment_never_reduces_cosine(n, d, bits, seed):
    x = np.random.default_rng(seed).standard_normal((n, d)) \
        .astype(np.float32)
    c0 = np.asarray(caq_encode(x, bits=bits, rounds=0).cosine())
    c4 = np.asarray(caq_encode(x, bits=bits, rounds=4).cosine())
    assert (c4 >= c0 - 1e-5).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 7), st.integers(0, 2 ** 31 - 1))
def test_prefix_shift_identity(b, seed):
    x = np.random.default_rng(seed).standard_normal((6, 12)) \
        .astype(np.float32)
    full = caq_encode(x, bits=8, rounds=2)
    pre = caq_prefix(full, b)
    np.testing.assert_array_equal(
        np.asarray(pre.codes), np.asarray(full.codes) >> (8 - b))


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 32), st.floats(0.0, 2.0), st.integers(1, 10),
       st.integers(0, 2 ** 31 - 1))
def test_plan_respects_quota_and_coverage(d, alpha, avg_bits, seed):
    v = (np.arange(1, d + 1, dtype=np.float64) ** -alpha)
    rng = np.random.default_rng(seed)
    v = v * rng.uniform(0.5, 2.0, d)
    v = np.sort(v)[::-1].copy()
    quota = avg_bits * d
    plan = search_plan(v, quota, align=max(1, d // 4), max_bits=12)
    assert plan.total_bits <= quota
    assert plan.segments[0].start == 0
    assert plan.segments[-1].stop == d
    assert plan_error(plan, v) >= 0


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([4, 8, 16, 32, 64]), st.integers(1, 6),
       st.integers(0, 2 ** 31 - 1))
def test_fwht_preserves_norm(d, n, seed):
    x = np.random.default_rng(seed).standard_normal((n, d)) \
        .astype(np.float32)
    y = np.asarray(fwht(jnp.asarray(x))) / np.sqrt(d)
    np.testing.assert_allclose((y ** 2).sum(-1), (x ** 2).sum(-1),
                               rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(1, 8), st.sampled_from([2, 4, 8]),
       st.integers(0, 2 ** 31 - 1))
def test_kv_pack_unpack_roundtrip(n, d_words, bits, seed):
    from repro.kernels.packbody import kv_pack, kv_unpack
    rng = np.random.default_rng(seed)
    hd = d_words * 32 // bits          # whole number of words per row
    codes = jnp.asarray(rng.integers(0, 1 << bits, (n, hd)), jnp.uint8)
    packed = kv_pack(codes, bits)
    assert packed.shape[-1] == hd * bits // 32
    assert packed.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(kv_unpack(packed, hd, bits)),
                                  np.asarray(codes))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(4, 32), st.integers(1, 8),
       st.integers(0, 2 ** 31 - 1))
def test_optimizer_moment_quantization_roundtrip(n, d, bits_pow,
                                                 seed):
    from repro.train.optimizer import _q_decode, _q_encode
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)) * 10, jnp.float32)
    q = _q_encode(x, 8)
    back = _q_decode(q, 8)
    assert back.shape == x.shape
    # blockwise midpoint grid: error bounded by delta/2 per block
    err = np.abs(np.asarray(back) - np.asarray(x))
    vmax = np.asarray(q.vmax)
    # every element's error <= its block's delta (loose: delta = 2vmax/256)
    assert err.max() <= vmax.max() * 2 / 256 + 1e-5
