import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def decaying_data(n, d, alpha=0.7, seed=0):
    """Gaussian data with power-law spectrum, rotated (PCA non-trivial)."""
    r = np.random.default_rng(seed)
    s = (np.arange(1, d + 1) ** -alpha).astype(np.float32)
    g = r.standard_normal((d, d))
    q, rr = np.linalg.qr(g)
    rot = (q * np.sign(np.diag(rr))).astype(np.float32)
    return ((r.standard_normal((n, d)).astype(np.float32) * s) @ rot.T)
