import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.runtime import FailureInjector, StragglerMonitor, Supervisor
from repro.runtime.elastic import make_shardings, reshard_tree


def test_ckpt_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}}
    mgr.save(3, tree, blocking=True)
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    out = mgr.restore(3, like)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


def test_ckpt_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros(4)}
    for s in (1, 5, 9):
        mgr.save(s, tree, blocking=True)
    assert mgr.latest_step() == 9
    assert mgr.all_steps() == [5, 9]          # step 1 GC'd


def test_ckpt_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"x": jnp.zeros(4)}
    mgr.save(1, tree, blocking=True)
    # a stale .tmp dir must never be listed
    os.makedirs(tmp_path / "step_0000000002.tmp", exist_ok=True)
    assert mgr.all_steps() == [1]


def test_supervisor_recovers_to_identical_state(tmp_path):
    """Failure injection + restart == failure-free run (bit-identical)."""
    def step_fn(state, step):
        new = jax.tree_util.tree_map(
            lambda x: x + (step + 1) * 0.5, state)
        return new, {"loss": float(step)}

    def run(root, injector):
        mgr = CheckpointManager(root, keep=3)
        sup = Supervisor(step_fn=step_fn, ckpt=mgr, ckpt_every=3)
        state = {"w": jnp.zeros(4)}
        return sup.run(state, 10, injector)

    clean, _ = run(str(tmp_path / "clean"), None)
    faulty, hist = run(str(tmp_path / "faulty"),
                       FailureInjector(fail_at=[4, 8]))
    assert hist["restarts"] == 2
    np.testing.assert_array_equal(np.asarray(clean["w"]),
                                  np.asarray(faulty["w"]))


def test_supervisor_resumes_from_existing_ckpt(tmp_path):
    calls = []

    def step_fn(state, step):
        calls.append(step)
        return jax.tree_util.tree_map(lambda x: x + 1, state), {}

    mgr = CheckpointManager(str(tmp_path), keep=3)
    sup = Supervisor(step_fn=step_fn, ckpt=mgr, ckpt_every=2)
    state = {"w": jnp.zeros(2)}
    sup.run(state, 5)
    calls.clear()
    sup2 = Supervisor(step_fn=step_fn, ckpt=mgr, ckpt_every=2)
    final, _ = sup2.run(state, 8)
    assert min(calls) == 5                     # resumed, not replayed
    np.testing.assert_array_equal(np.asarray(final["w"]),
                                  np.full(2, 8.0))


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(warmup=3, k=3.0)
    for i in range(10):
        assert not mon.observe(i, 1.0 + 0.01 * (i % 2))
    assert mon.observe(10, 5.0)                # 5x the mean
    assert 10 in mon.flagged_steps
    assert not mon.observe(11, 1.0)


def test_elastic_reshard():
    from jax.sharding import PartitionSpec as P
    mesh1 = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    spec = {"w": P("data", None)}
    out = reshard_tree(tree, spec, mesh1)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    # non-divisible axis falls back to replication rather than crashing
    tree2 = {"w": jnp.arange(6.0).reshape(3, 2)}
    mesh2 = jax.make_mesh((1,), ("model",))
    out2 = reshard_tree(tree2, {"w": P("model", None)}, mesh2)
    np.testing.assert_array_equal(np.asarray(out2["w"]),
                                  np.asarray(tree2["w"]))
