import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kmeans import kmeans_fit
from repro.core.saq import SAQConfig
from repro.ivf import IVFIndex
from repro.ivf.index import brute_force_topk
from conftest import decaying_data


@pytest.fixture(scope="module")
def built():
    x = decaying_data(4000, 48, alpha=0.7, seed=0)
    idx = IVFIndex.build(
        x, SAQConfig(avg_bits=4, rounds=3, align=8, max_bits=9),
        n_clusters=24)
    return x, idx


def test_kmeans_reduces_inertia():
    x = decaying_data(1000, 16, seed=1)
    r1 = kmeans_fit(jnp.asarray(x), k=8, iters=1)
    r20 = kmeans_fit(jnp.asarray(x), k=8, iters=20)
    assert float(r20.inertia) < float(r1.inertia)
    assert len(np.unique(np.asarray(r20.assignments))) > 4


def test_ivf_recall(built):
    x, idx = built
    qs = decaying_data(8, 48, alpha=0.7, seed=50)
    recalls = []
    for i in range(qs.shape[0]):
        gt, _ = brute_force_topk(jnp.asarray(x), jnp.asarray(qs[i]), 10)
        ids, _ = idx.search(qs[i], k=10, nprobe=8)
        recalls.append(len(set(np.asarray(gt).tolist())
                           & set(np.asarray(ids).tolist())) / 10)
    assert np.mean(recalls) >= 0.8, recalls


def test_multistage_matches_full_and_prunes(built):
    x, idx = built
    qs = decaying_data(5, 48, alpha=0.7, seed=60)
    for i in range(qs.shape[0]):
        ids_f, _ = idx.search(qs[i], k=10, nprobe=8)
        ids_m, _, stats = idx.search_multistage(qs[i], k=10, nprobe=8,
                                                m=4.0)
        overlap = len(set(np.asarray(ids_f).tolist())
                      & set(np.asarray(ids_m).tolist()))
        assert overlap >= 8, overlap
        assert stats.bits_accessed < idx.plan.total_bits
        assert 0.0 <= stats.pruned_frac <= 1.0


def test_progressive_search(built):
    x, idx = built
    q = decaying_data(1, 48, alpha=0.7, seed=70)[0]
    n_seg = len(idx.plan.stored_segments)
    pb = [max(1, s.bits // 2) for s in idx.plan.stored_segments]
    ids, dists = idx.search(q, k=10, nprobe=8, prefix_bits=pb)
    gt, _ = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
    overlap = len(set(np.asarray(gt).tolist())
                  & set(np.asarray(ids).tolist()))
    assert overlap >= 5


def test_index_save_load_roundtrip(built, tmp_path):
    from repro.ivf import load_index, save_index
    x, idx = built
    q = decaying_data(1, 48, alpha=0.7, seed=99)[0]
    ids_a, d_a = idx.search(q, k=5, nprobe=8)
    save_index(idx, str(tmp_path / "index"))
    idx2 = load_index(str(tmp_path / "index"))
    ids_b, d_b = idx2.search(q, k=5, nprobe=8)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    np.testing.assert_allclose(np.asarray(d_a), np.asarray(d_b),
                               rtol=1e-5)


def test_search_batch(built):
    x, idx = built
    qs = decaying_data(4, 48, alpha=0.7, seed=77)
    ids, dists = idx.search_batch(qs, k=5, nprobe=8)
    assert ids.shape == (4, 5) and dists.shape == (4, 5)
    for i in range(4):
        a, _ = idx.search(qs[i], k=5, nprobe=8)
        np.testing.assert_array_equal(np.asarray(ids[i]), np.asarray(a))
