import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kmeans import kmeans_fit
from repro.core.saq import SAQConfig
from repro.ivf import IVFIndex
from repro.ivf.index import brute_force_topk
from conftest import decaying_data


@pytest.fixture(scope="module")
def built():
    x = decaying_data(4000, 48, alpha=0.7, seed=0)
    idx = IVFIndex.build(
        x, SAQConfig(avg_bits=4, rounds=3, align=8, max_bits=9),
        n_clusters=24)
    return x, idx


def test_kmeans_reduces_inertia():
    x = decaying_data(1000, 16, seed=1)
    r1 = kmeans_fit(jnp.asarray(x), k=8, iters=1)
    r20 = kmeans_fit(jnp.asarray(x), k=8, iters=20)
    assert float(r20.inertia) < float(r1.inertia)
    assert len(np.unique(np.asarray(r20.assignments))) > 4


def test_ivf_recall(built):
    x, idx = built
    qs = decaying_data(8, 48, alpha=0.7, seed=50)
    recalls = []
    for i in range(qs.shape[0]):
        gt, _ = brute_force_topk(jnp.asarray(x), jnp.asarray(qs[i]), 10)
        ids, _ = idx.search(qs[i], k=10, nprobe=8)
        recalls.append(len(set(np.asarray(gt).tolist())
                           & set(np.asarray(ids).tolist())) / 10)
    assert np.mean(recalls) >= 0.8, recalls


def test_multistage_matches_full_and_prunes(built):
    x, idx = built
    qs = decaying_data(5, 48, alpha=0.7, seed=60)
    for i in range(qs.shape[0]):
        ids_f, _ = idx.search(qs[i], k=10, nprobe=8)
        ids_m, _, stats = idx.search_multistage(qs[i], k=10, nprobe=8,
                                                m=4.0)
        overlap = len(set(np.asarray(ids_f).tolist())
                      & set(np.asarray(ids_m).tolist()))
        assert overlap >= 8, overlap
        assert stats.bits_accessed < idx.plan.total_bits
        assert 0.0 <= stats.pruned_frac <= 1.0


def test_staged_scan_consts_cached_per_index(built):
    """search_multistage visits many clusters per call; the staged-scan
    constants (variance segment slices / bounds / dropped-dim mask) are
    pure per-index values and must be built ONCE and reused across
    clusters and calls — not rebuilt in Python per probed cluster."""
    from repro.ivf import index as ivf_index
    _, idx = built
    q = decaying_data(1, 48, alpha=0.7, seed=65)[0]
    idx.__dict__.pop("_staged_consts_cache", None)
    builds = {"n": 0}
    real = ivf_index._staged_scan_consts

    def counting(index):
        had = "_staged_consts_cache" in index.__dict__
        out = real(index)
        if not had:
            builds["n"] += 1
        return out

    ivf_index._staged_scan_consts = counting
    try:
        ids1, d1, _ = idx.search_multistage(q, k=10, nprobe=8)
        first = idx._staged_consts_cache
        ids2, d2, _ = idx.search_multistage(q, k=10, nprobe=8)
    finally:
        ivf_index._staged_scan_consts = real
    assert builds["n"] == 1                   # built exactly once...
    assert idx._staged_consts_cache is first  # ...and reused verbatim
    np.testing.assert_array_equal(np.asarray(ids1), np.asarray(ids2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_progressive_search(built):
    x, idx = built
    q = decaying_data(1, 48, alpha=0.7, seed=70)[0]
    n_seg = len(idx.plan.stored_segments)
    pb = [max(1, s.bits // 2) for s in idx.plan.stored_segments]
    ids, dists = idx.search(q, k=10, nprobe=8, prefix_bits=pb)
    gt, _ = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
    overlap = len(set(np.asarray(gt).tolist())
                  & set(np.asarray(ids).tolist()))
    assert overlap >= 5


def test_index_save_load_roundtrip(built, tmp_path):
    from repro.ivf import load_index, save_index
    x, idx = built
    q = decaying_data(1, 48, alpha=0.7, seed=99)[0]
    ids_a, d_a = idx.search(q, k=5, nprobe=8)
    save_index(idx, str(tmp_path / "index"))
    idx2 = load_index(str(tmp_path / "index"))
    ids_b, d_b = idx2.search(q, k=5, nprobe=8)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    np.testing.assert_allclose(np.asarray(d_a), np.asarray(d_b),
                               rtol=1e-5)


def test_search_batch(built):
    x, idx = built
    qs = decaying_data(4, 48, alpha=0.7, seed=77)
    ids, dists = idx.search_batch(qs, k=5, nprobe=8)
    assert ids.shape == (4, 5) and dists.shape == (4, 5)
    for i in range(4):
        a, _ = idx.search(qs[i], k=5, nprobe=8)
        np.testing.assert_array_equal(np.asarray(ids[i]), np.asarray(a))


def test_multistage_validates_k_like_search_batch(built):
    """search_multistage applies the same k/nprobe validation as
    search_batch instead of silently returning -1/inf rows."""
    _, idx = built
    q = decaying_data(1, 48, alpha=0.7, seed=88)[0]
    l_max = int(idx.ids.shape[1])
    with pytest.raises(ValueError, match="candidate capacity"):
        idx.search_multistage(q, k=l_max + 1, nprobe=1)
    with pytest.raises(ValueError):
        idx.search_multistage(q, k=0, nprobe=4)
    with pytest.raises(ValueError):
        idx.search_multistage(q, k=5, nprobe=0)
    # valid boundary still works
    ids, _, _ = idx.search_multistage(q, k=5, nprobe=4)
    assert ids.shape == (5,)


@pytest.mark.parametrize("bitpacked", [True, False])
def test_multistage_vs_batch_parity(built, bitpacked):
    """With pruning disabled (huge m) and nprobe = C, the multistage
    path scans exactly the candidates search_batch scans: top-k ids
    must match exactly and distances to fp-accumulation-order noise."""
    import dataclasses

    _, idx = built
    if not bitpacked:
        idx = dataclasses.replace(idx, packed=idx.packed.unpack())
    assert idx.packed.bitpacked == bitpacked
    qs = decaying_data(4, 48, alpha=0.7, seed=91)
    for i in range(qs.shape[0]):
        ids_b, d_b = idx.search(qs[i], k=10, nprobe=idx.n_clusters)
        ids_m, d_m, st = idx.search_multistage(
            qs[i], k=10, nprobe=idx.n_clusters, m=1e9)
        assert st.pruned_frac == 0.0           # m disables pruning
        np.testing.assert_array_equal(np.asarray(ids_b),
                                      np.asarray(ids_m))
        np.testing.assert_allclose(np.asarray(d_b), np.asarray(d_m),
                                   rtol=1e-5, atol=1e-5)


def _ragged_index():
    """An index whose probed lists are much shorter than the padded L,
    so k <= min(nprobe, C) * L passes validation but the scan runs out
    of real candidates."""
    rng = np.random.default_rng(7)
    blobs = rng.standard_normal((3, 16)).astype(np.float32) * 4.0
    x = np.concatenate([
        np.repeat(blobs[j:j + 1], n, axis=0)
        + rng.standard_normal((n, 16)).astype(np.float32) * 0.05
        for j, n in enumerate((30, 3, 3))])
    idx = IVFIndex.build(
        x, SAQConfig(avg_bits=4, rounds=2, align=8, max_bits=9),
        n_clusters=3)
    assert int(np.asarray(idx.counts).min()) < int(idx.ids.shape[1])
    return blobs, idx


def test_ragged_padding_contract():
    """The documented short-candidate contract (see _validate_k): when
    valid candidates < k <= padded capacity, every path returns the
    real candidates first (distances ascending) and fills the tail with
    id -1 / dist inf — batch (both scan layouts) and multistage."""
    blobs, idx = _ragged_index()
    q = blobs[1]
    k = 10

    def check(ids, dists):
        ids, dists = np.asarray(ids), np.asarray(dists)
        n_real = int((ids >= 0).sum())
        assert 0 < n_real < k                  # the edge is actually hit
        assert (ids[:n_real] >= 0).all()       # real rows first...
        assert (ids[n_real:] == -1).all()      # ...-1 tail last
        assert np.isfinite(dists[:n_real]).all()
        assert np.isinf(dists[n_real:]).all()
        assert (np.diff(dists[:n_real]) >= 0).all()
        return ids, dists

    ids_g, d_g = idx.search(q, k=k, nprobe=1)
    check(ids_g, d_g)
    ids_c, d_c = idx.search_batch(q[None], k=k, nprobe=1,
                                  backend="xla-cluster-major")
    check(ids_c[0], d_c[0])
    np.testing.assert_array_equal(np.asarray(ids_g), np.asarray(ids_c[0]))
    ids_m, d_m, _ = idx.search_multistage(q, k=k, nprobe=1, m=1e9)
    check(ids_m, d_m)
    np.testing.assert_array_equal(np.asarray(ids_g), np.asarray(ids_m))
