import itertools

import numpy as np
import pytest

from repro.core.plan import plan_error, search_plan
from repro.core.types import QuantPlan, SegmentSpec


def brute_force_best(variances, quota, align, max_bits):
    d = len(variances)
    bounds = list(range(0, d, align)) + [d]
    bounds = sorted(set(bounds))
    best = (np.inf, None)
    positions = bounds[1:-1]
    for r in range(len(positions) + 1):
        for cuts in itertools.combinations(positions, r):
            edges = [0] + list(cuts) + [d]
            segs = [(edges[i], edges[i + 1]) for i in range(len(edges) - 1)]
            for bits in itertools.product(range(max_bits + 1),
                                          repeat=len(segs)):
                cost = sum(b * (e - s) for (s, e), b in zip(segs, bits))
                if cost > quota:
                    continue
                plan = QuantPlan(d, tuple(
                    SegmentSpec(s, e, b) for (s, e), b in zip(segs, bits)))
                err = plan_error(plan, variances)
                if err < best[0]:
                    best = (err, plan)
    return best


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dp_matches_brute_force(seed):
    r = np.random.default_rng(seed)
    d, align, max_bits = 8, 2, 3
    variances = np.sort(r.uniform(0.01, 1.0, d))[::-1].copy()
    quota = 2 * d
    plan = search_plan(variances, quota, align=align, max_bits=max_bits)
    err = plan_error(plan, variances)
    best_err, _ = brute_force_best(variances, quota, align, max_bits)
    assert err <= best_err * 1.001 + 1e-12
    assert plan.total_bits <= quota


def test_quota_respected():
    v = (np.arange(1, 65) ** -0.8)[::-1].copy()
    for avg in [0.5, 2, 4, 9]:
        plan = search_plan(v, int(avg * 64), align=8, max_bits=12)
        assert plan.total_bits <= int(avg * 64)


def test_flat_spectrum_single_segment():
    v = np.ones(64)
    plan = search_plan(v, 4 * 64, align=8, max_bits=8)
    # uniform spectrum: one segment at uniform bits is optimal (paper §4.2)
    assert len(plan.segments) == 1
    assert plan.segments[0].bits == 4


def test_decaying_spectrum_allocates_more_to_leading():
    v = (np.arange(1, 129, dtype=np.float64) ** -1.5)
    plan = search_plan(v, 4 * 128, align=16, max_bits=12)
    bits = [s.bits for s in plan.segments]
    assert bits == sorted(bits, reverse=True)
    assert bits[0] > bits[-1]


def test_plan_validation():
    with np.testing.assert_raises(ValueError):
        QuantPlan(10, (SegmentSpec(0, 4, 2), SegmentSpec(5, 10, 2)))
    with np.testing.assert_raises(ValueError):
        QuantPlan(10, (SegmentSpec(0, 4, 2),))
