import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rotation import (PCA, DenseRotation, FWHTRotation, fwht,
                                 random_orthonormal)
from conftest import decaying_data


def test_random_orthonormal():
    r = np.asarray(random_orthonormal(jax.random.PRNGKey(0), 32))
    np.testing.assert_allclose(r @ r.T, np.eye(32), atol=1e-5)


def test_dense_rotation_preserves_ip():
    rot = DenseRotation(24, seed=1)
    x = np.random.default_rng(0).standard_normal((5, 24)).astype(np.float32)
    y = np.asarray(rot.apply(x))
    np.testing.assert_allclose(x @ x.T, y @ y.T, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(rot.inverse(y)), x, atol=1e-4)


def test_fwht_orthonormal_and_involution():
    x = np.random.default_rng(1).standard_normal((3, 64)).astype(np.float32)
    y = np.asarray(fwht(jnp.asarray(x))) / 8.0     # normalized
    np.testing.assert_allclose((y ** 2).sum(-1), (x ** 2).sum(-1), rtol=1e-4)
    # H/sqrt(D) is an involution
    z = np.asarray(fwht(jnp.asarray(y))) / 8.0
    np.testing.assert_allclose(z, x, atol=1e-4)


def test_fwht_rotation_padding():
    rot = FWHTRotation(48, seed=0)            # pads to 64
    x = np.random.default_rng(2).standard_normal((4, 48)).astype(np.float32)
    y = np.asarray(rot.apply(jnp.asarray(x)))
    assert y.shape == (4, 64)
    np.testing.assert_allclose((y ** 2).sum(-1), (x ** 2).sum(-1), rtol=1e-4)
    back = np.asarray(rot.inverse(jnp.asarray(y)))
    np.testing.assert_allclose(back, x, atol=1e-4)


def test_pca_orders_variance():
    x = decaying_data(2000, 24, alpha=1.0)
    pca = PCA.fit(jnp.asarray(x))
    v = np.asarray(pca.variances)
    assert (np.diff(v) <= 1e-5).all()
    proj = np.asarray(pca.apply(jnp.asarray(x)))
    emp = proj.var(axis=0)
    np.testing.assert_allclose(emp, v, rtol=0.05, atol=1e-4)
    # distances preserved
    d0 = ((x[0] - x[1]) ** 2).sum()
    d1 = ((proj[0] - proj[1]) ** 2).sum()
    np.testing.assert_allclose(d0, d1, rtol=1e-3)
