"""The trip-count-aware HLO analyzer vs known-cost programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_cost


def _compile(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(fn).lower(*args).compile()


BASE = 2 * 128 ** 3   # one 128^3 matmul


def test_single_matmul_flops():
    t = hlo_cost.analyze(_compile(lambda x, w: x @ w, (128, 128),
                                  (128, 128)).as_text())
    assert abs(t.flops - BASE) / BASE < 0.01


def test_scan_multiplies_by_trip_count():
    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out
    t = hlo_cost.analyze(_compile(scanned, (128, 128),
                                  (128, 128)).as_text())
    assert abs(t.flops - 10 * BASE) / (10 * BASE) < 0.01
    assert 10 in t.trip_counts.values()


def test_nested_scan():
    def nested(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out
    t = hlo_cost.analyze(_compile(nested, (128, 128),
                                  (128, 128)).as_text())
    assert abs(t.flops - 15 * BASE) / (15 * BASE) < 0.01


def test_grad_of_scan_counts_fwd_and_bwd():
    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return jnp.sum(out ** 2)
    t = hlo_cost.analyze(_compile(jax.grad(scanned, argnums=1),
                                  (128, 128), (128, 128)).as_text())
    # fwd 10 + recompute-for-bwd 10 + two bwd matmuls... >= 30 dots
    assert t.flops >= 30 * BASE * 0.99


def test_stock_cost_analysis_undercounts():
    """Documents WHY this module exists."""
    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out
    compiled = _compile(scanned, (128, 128), (128, 128))
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert ca["flops"] < 2 * BASE          # counts the body once
    t = hlo_cost.analyze(compiled.as_text())
    assert t.flops > 9 * BASE              # we do not


def test_shape_parsing_tuples_and_dtypes():
    from repro.launch.hlo_cost import _shape_bytes
    assert _shape_bytes("f32[2,3]{1,0}") == 24
    assert _shape_bytes("(f32[4]{0}, bf16[2,2]{1,0})") == 16 + 8
    assert _shape_bytes("u8[10]") == 10
    assert _shape_bytes("pred[]") == 1
    assert _shape_bytes("s32[2,2]") == 16


def test_collectives_counted_with_multiplier():
    import os
    import subprocess
    import sys
    import textwrap
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.launch.hlo_cost import analyze
        mesh = make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
        def f(x, w):
            def body(c, _):
                y = c @ w
                y = jax.lax.with_sharding_constraint(y, P("data", None))
                return y, None
            out, _ = jax.lax.scan(body, x, None, length=7)
            return jnp.sum(out)
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        sh = NamedSharding(mesh, P(None, "data"))
        with set_mesh(mesh):
            c = jax.jit(f, in_shardings=(sh, sh)).lower(x, w).compile()
        t = analyze(c.as_text())
        print("TRIPS", sorted(t.trip_counts.values()))
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "7" in out.stdout
